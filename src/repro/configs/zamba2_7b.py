"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000 ssm_state=64
[arXiv:2411.15242; unverified]
Shared attn block applied before every 6th SSM block (weight-tied).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid", layers=81, d_model=3584,
        n_heads=32, kv_heads=32, head_dim=112, d_ff=14336, vocab=32000,
        ssm_state=64, ssm_head_dim=64, attn_every=6,
    )
