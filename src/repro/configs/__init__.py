"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

from typing import Dict, List

from repro.configs import (
    bitnet_1_58b,
    bitnet_1_58b_kv,
    granite_20b,
    granite_moe_1b_a400m,
    granite_moe_3b_a800m,
    hubert_xlarge,
    internvl2_76b,
    mamba2_130m,
    qwen3_1_7b,
    smollm_360m,
    starcoder2_3b,
    zamba2_7b,
)
from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
    shape_by_name,
)

_MODULES = {
    "granite-20b": granite_20b,
    "smollm-360m": smollm_360m,
    "starcoder2-3b": starcoder2_3b,
    "qwen3-1.7b": qwen3_1_7b,
    "zamba2-7b": zamba2_7b,
    "mamba2-130m": mamba2_130m,
    "granite-moe-1b-a400m": granite_moe_1b_a400m,
    "granite-moe-3b-a800m": granite_moe_3b_a800m,
    "hubert-xlarge": hubert_xlarge,
    "internvl2-76b": internvl2_76b,
    "bitnet-1.58b": bitnet_1_58b,
    "bitnet-1.58b-kv": bitnet_1_58b_kv,
}

ASSIGNED_ARCHS: List[str] = [
    "granite-20b", "smollm-360m", "starcoder2-3b", "qwen3-1.7b",
    "zamba2-7b", "mamba2-130m", "granite-moe-1b-a400m",
    "granite-moe-3b-a800m", "hubert-xlarge", "internvl2-76b",
]


def arch_names() -> List[str]:
    return list(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_MODULES)}")
    return _MODULES[name].config()


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Small same-family variant for CPU smoke tests (assignment: reduced
    layers/width/experts/vocab; one forward/train step must run on CPU)."""
    kw = dict(
        name=cfg.name + "-smoke",
        layers=4 if cfg.family == "hybrid" else 2,
        d_model=128,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab=512,
        max_seq=128,
        remat="none",
    )
    if cfg.n_heads:
        kw.update(
            n_heads=4,
            kv_heads=1 if cfg.kv_heads == 1 else (
                4 if cfg.kv_heads == cfg.n_heads else 2
            ),
            head_dim=32,
        )
    if cfg.n_experts:
        kw.update(n_experts=8, top_k=2, d_ff=64, n_experts_padded=0)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=16, ssd_chunk=32)
    if cfg.family == "hybrid":
        kw.update(attn_every=2)
    if cfg.frontend == "vision_patches":
        kw.update(num_patches=8)
    return cfg.replace(**kw)


# Shape applicability (DESIGN.md SS4): which cells run vs. are skipped.
def applicable_shapes(cfg: ModelConfig) -> Dict[str, str]:
    """shape name -> "run" or reason for skipping."""
    out: Dict[str, str] = {}
    for shape in ALL_SHAPES:
        if shape.kind == "decode" and not cfg.is_decoder:
            out[shape.name] = "skip: encoder-only arch has no decode step"
        elif (shape.name == "long_500k"
              and cfg.family not in ("ssm", "hybrid")):
            out[shape.name] = (
                "skip: 512k decode needs sub-quadratic attention; arch is "
                "pure full-attention"
            )
        else:
            out[shape.name] = "run"
    return out
