"""smollm-360m [dense] — llama-arch small model.

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152
[hf:HuggingFaceTB/SmolLM-135M; hf]
15 heads do not divide the 16-wide model axis: attention is replicated and
TP lands on d_ff (sharding rules fall back automatically).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", family="dense", layers=32, d_model=960,
        n_heads=15, kv_heads=5, head_dim=64, d_ff=2560, vocab=49152,
    )
