"""granite-20b [dense] — llama-arch code model, extreme MQA (kv=1).

52L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152 [arXiv:2405.04324; hf]
kv=1 is the paper's best-case KV-multicast regime (reuse factor H/G = 48).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b", family="dense", layers=52, d_model=6144,
        n_heads=48, kv_heads=1, head_dim=128, d_ff=24576, vocab=49152,
    )
