"""BitNet-1.58B-KV — the paper's GQA variant (4 KV heads, SS V)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="bitnet-1.58b-kv", family="dense", layers=32, d_model=2560,
        n_heads=16, kv_heads=4, head_dim=128, d_ff=6912, vocab=32000,
        max_seq=2048,
    )
