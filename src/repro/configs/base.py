"""ModelConfig — one dataclass covering every assigned architecture family."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encoder | vlm
    layers: int
    d_model: int
    n_heads: int                 # 0 for attention-free (ssm)
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 => d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # pad the expert dim to a mesh-divisible count (dummy experts hold zero
    # weights and receive no tokens); 0 = no padding
    n_experts_padded: int = 0
    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    ssd_chunk: int = 128
    # --- hybrid (Zamba2-style shared attention) ---
    attn_every: int = 0          # shared attn applied before every k-th block
    # --- modality frontend stubs ---
    frontend: str = "none"       # none | audio_frames | vision_patches
    num_patches: int = 0         # VLM: patches prepended to the sequence
    # --- quantization (BitNet b1.58 QAT on projections) ---
    quantization: str = "bitnet"   # bitnet | none
    weight_bits: int = 2
    # --- runtime ---
    causal: bool = True
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    remat: str = "block"          # none | block (checkpoint each layer block)
    kernel_backend: str = "reference"   # reference | pallas (TPU)
    max_seq: int = 4096

    # ------------------------------------------------------------------ #
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attn_inner(self) -> int:
        return self.n_heads * self.head_dim_

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def group_size(self) -> int:
        return self.n_heads // max(self.kv_heads, 1)

    @property
    def n_experts_total(self) -> int:
        """Expert-dim size incl. sharding padding (>= n_experts)."""
        return max(self.n_experts_padded, self.n_experts)

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def is_decoder(self) -> bool:
        return self.family != "encoder"

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------ #
    def param_count(self) -> int:
        """Analytic parameter count (used for 6*N*D model-FLOPs)."""
        d, l = self.d_model, self.layers
        n = self.vocab * d                       # embeddings
        if not self.tie_embeddings:
            n += self.vocab * d
        per_layer = 0
        if self.family in ("dense", "moe", "encoder", "vlm"):
            hd = self.head_dim_
            per_layer += d * self.n_heads * hd + 2 * d * self.kv_heads * hd
            per_layer += self.n_heads * hd * d   # out proj
            per_layer += 2 * d                   # norms
            if self.family == "moe":
                per_layer += d * self.n_experts  # router
                per_layer += self.n_experts * 3 * d * self.d_ff
            else:
                per_layer += 3 * d * self.d_ff   # swiglu
        elif self.family in ("ssm", "hybrid"):
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            in_proj = d * (2 * di + 2 * ns + nh)
            out_proj = di * d
            per_layer += in_proj + out_proj + 3 * nh + d  # +dt/A/D + norm
        n += per_layer * l
        if self.family == "hybrid" and self.attn_every:
            hd = self.head_dim_
            shared = d * self.n_heads * hd + 2 * d * self.kv_heads * hd
            shared += self.n_heads * hd * d + 3 * d * self.d_ff + 2 * d
            n += shared                          # one shared block (Zamba2)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, l = self.d_model, self.layers
        inactive = (self.n_experts - self.top_k) * 3 * d * self.d_ff * l
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """An assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str      # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES: Tuple[ShapeConfig, ...] = (
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
