"""granite-moe-1b-a400m [moe] — 32 experts, top-8.

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe", layers=24, d_model=1024,
        n_heads=16, kv_heads=8, head_dim=64, d_ff=512, vocab=49155,
        n_experts=32, top_k=8,
    )
