"""hubert-xlarge [audio] — encoder-only transformer backbone.

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 [arXiv:2106.07447;
unverified].  The conv feature extractor is a STUB: input_specs provides
precomputed frame embeddings [B, S, d_model].  No decode step exists —
decode shape cells are skipped (DESIGN.md).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="encoder", layers=48, d_model=1280,
        n_heads=16, kv_heads=16, head_dim=80, d_ff=5120, vocab=504,
        causal=False, frontend="audio_frames", tie_embeddings=False,
    )
