"""Design-space exploration + ZTB sparsity sweep (paper SS III / SS IV-A.4).

    PYTHONPATH=src python examples/sparsity_dse.py
"""
import numpy as np

from repro.core import (
    attention_workloads,
    bitnet_1_58b,
    dlegion,
    simulate,
)
from repro.core.analytical import cri, tfu_cycles, unit_input_bandwidth
from repro.core.config import AcceleratorConfig, Dataflow
from repro.core.sparsity import ZTBStats
from repro.core.workloads import corner_case_workloads


def legion_cfg(c, d):
    return AcceleratorConfig(
        name=f"{c}x{d}x{d}", dataflow=Dataflow.ADIP, units=1, cores=c, d=d,
        pipeline=4, adaptive=True, packed_weights=True,
    )


print("== Legion granularity (paper Fig. 3/4) ==")
wl = corner_case_workloads()
print(f"{'config':>10s} {'PEs':>6s} {'TFU':>4s} {'in-BW':>6s} {'CRI':>8s}")
for c, d in [(2, 64), (4, 32), (8, 16), (16, 8)]:
    cfg = legion_cfg(c, d)
    print(f"{cfg.name:>10s} {cfg.total_pes:>6d} {tfu_cycles(cfg):>4d} "
          f"{unit_input_bandwidth(cfg):>6d} {cri(cfg, wl):>8.0f}")
print("-> 8x16x16 selected (highest CRI among configs with 2x the PEs of "
      "16x8x8), matching the paper.\n")

print("== ZTB block-structured sparsity sweep (D-Legion, BitNet-1.58B) ==")
wl = attention_workloads(bitnet_1_58b())
dense = simulate(dlegion(), wl)
print(f"{'window sparsity':>16s} {'latency x':>10s} {'memory x':>9s} "
      f"{'psum x':>7s}")
for frac in (0.0, 0.25, 0.5, 0.75):
    ztb = ZTBStats(fully_sparse_fraction=frac, zero_tile_fraction=frac,
                   num_windows=100, num_tiles=800)
    rep = simulate(dlegion(), wl, ztb=ztb)
    print(f"{frac:>16.2f} {dense.total_cycles/rep.total_cycles:>10.2f} "
          f"{dense.total_mem_gb/rep.total_mem_gb:>9.2f} "
          f"{dense.total_psum_gb/rep.total_psum_gb:>7.2f}")
print("\n(fully-sparse windows skip compute, transfers and accumulator "
      "updates; act-to-act stages are unaffected — ZTB lives on weights)")
