"""End-to-end QAT training driver: a ~100M-param BitNet-style model on the
deterministic synthetic corpus, with checkpoints, preemption handling and
restart.

Full run (a few hundred steps of a ~100M model — sized for a real chip;
several hours on this 1-core CPU container):

    PYTHONPATH=src python examples/train_bitnet.py --steps 300

CI-scale smoke (default):

    PYTHONPATH=src python examples/train_bitnet.py --steps 20 --tiny
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data import synthetic_batch
from repro.models import build_model
from repro.train import (
    AdamW,
    Checkpointer,
    TrainingRunner,
    build_train_step,
    cosine_schedule,
    init_train_state,
)


def model_100m() -> ModelConfig:
    """~100M params, BitNet-1.58B family (ternary QAT)."""
    return ModelConfig(
        name="bitnet-100m", family="dense", layers=10, d_model=768,
        n_heads=12, kv_heads=4, head_dim=64, d_ff=2048, vocab=32000,
        max_seq=1024,
    )


def model_tiny() -> ModelConfig:
    return model_100m().replace(
        name="bitnet-tiny", layers=4, d_model=256, n_heads=4, kv_heads=2,
        head_dim=64, d_ff=512, vocab=2048, remat="none",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--grad-accum", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/bitnet_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args()

    cfg = model_tiny() if args.tiny else model_100m()
    api = build_model(cfg)
    n_params = cfg.param_count()
    print(f"arch={cfg.name}  params={n_params/1e6:.1f}M  "
          f"quantization={cfg.quantization}")

    opt = AdamW(lr=cosine_schedule(args.lr, warmup=10, total=args.steps))
    state = init_train_state(api, opt, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(api, opt, grad_accum=args.grad_accum))
    batch_fn = lambda s: {
        k: jnp.asarray(v) for k, v in
        synthetic_batch(cfg, batch=args.batch, seq=args.seq, step=s).items()
    }

    def log(s, m):
        if s % 5 == 0 or s == 1:
            print(f"step {s:5d}  loss={float(m['loss']):.4f}  "
                  f"gnorm={float(m['grad_norm']):.3f}")

    runner = TrainingRunner(
        step, batch_fn, state, Checkpointer(args.ckpt_dir),
        ckpt_every=args.ckpt_every, log_fn=log,
    )
    resumed = runner.maybe_restore()
    if resumed:
        print(f"resumed from checkpoint at step {resumed}")
    metrics = runner.run(args.steps)
    print(f"done: final loss={float(metrics['loss']):.4f} "
          f"(checkpoints in {args.ckpt_dir})")


if __name__ == "__main__":
    main()
