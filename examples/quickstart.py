"""Quickstart: the D-Legion stack in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. Reproduce the paper's headline comparison with the cycle simulator.
2. Run the packed-ternary bitlinear Pallas kernel (interpret mode).
3. Build a ZTB from a block-sparse weight and run the sparse kernel.
4. One QAT train step + one serving step of a tiny BitNet model.
5. Execute one attention stage through a `legion.Machine` session and
   cross-check its measured traffic against the simulator.
6. Drive one serving decode step's projection GEMMs through the serve-path
   Legion backend — per-token bytes AND cycles, cross-validated.
7. The Machine session API: one-liner runs, custom instruments, and the
   sharded executor backend (Legions on a JAX mesh axis, bit-exact).
8. The Program graph API: a full attention block (QKV -> score -> softmax
   -> output -> O-proj) as one dependency graph, bit-exact against a pure
   NumPy reference, with the PipelinedExecutor overlapping rounds of
   independent stages.
9. Serve pipelining: a two-explicit-layer program (layer 1's QKV streams
   layer 0's MLP output), a merged two-slot decode batch overlapping
   across slots, and the engine-view overlapped tokens/sec feeding the
   KV-cache budget.
10. Observability: a `TimelineTracer` instrument reconstructs the cycle
    timeline of a pipelined program (exact parity with the counter) and
    exports a Chrome/Perfetto trace; a `MetricsRegistry` snapshots the
    machine + serve metric families.
11. In-flight batching: chunked prefill merged with the decode batch into
    one Program per step, intake gated by `LiveAdmission` — bit-exact vs
    the legacy engine.
12. Paged KV cache: a page pool squeezed to force preemption mid-decode —
    evicted requests re-prefill and finish bit-exactly, while the Legion
    backend prices real page fetches and last-page waste.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    adip_64, attention_workloads, bitnet_1_58b, compare, dip_64, dlegion,
    simulate, ws_64,
)
from repro.core.sparsity import prune_block_structured, ztb_from_weight
from repro.kernels.bitlinear.kernel import bitlinear_matmul
from repro.kernels.block_sparse.ops import ztb_matmul
from repro.quant.packing import pack_2bit_kmajor

print("=" * 70)
print("1. Cycle simulator — D-Legion vs WS / DiP / ADiP (paper Figs. 7-10)")
wl = attention_workloads(bitnet_1_58b())
reports = [simulate(c, wl) for c in (ws_64(), dip_64(), adip_64(),
                                     dlegion())]
for r in reports:
    print(f"   {r.arch:14s} latency={r.total_seconds*1e3:8.2f} ms"
          f"  throughput={r.total_tops:6.2f} TOPS"
          f"  memory={r.total_mem_gb:6.2f} GB  psum={r.total_psum_gb:6.1f} GB")
ratios = compare(reports, "ADiP-64x64")["D-Legion-8L"]
print(f"   D-Legion vs ADiP: {ratios['latency_x']:.2f}x latency, "
      f"{ratios['mem_x']:.2f}x memory, {ratios['psum_x']:.2f}x psum")

print("=" * 70)
print("2. bitlinear kernel — ternary weights packed 4-per-byte")
rng = np.random.default_rng(0)
w = rng.integers(-1, 2, size=(512, 256)).astype(np.int8)
x = rng.integers(-128, 128, size=(128, 512)).astype(np.int8)
wp = pack_2bit_kmajor(jnp.asarray(w))
out = bitlinear_matmul(jnp.asarray(x), wp, interpret=True)
assert (np.asarray(out) == x.astype(np.int32) @ w.astype(np.int32)).all()
print(f"   x[{x.shape}] @ packed w[{wp.shape} uint8] == int32 GEMM: OK "
      f"(weight bytes: {w.size * 2}B bf16 -> {wp.size}B packed, "
      f"{w.size * 2 / wp.size:.0f}x less)")

print("=" * 70)
print("3. ZTB block-sparse kernel — fully-sparse windows never touched")
wf = rng.standard_normal((512, 384)).astype(np.float32)
wf = prune_block_structured(wf, block_k=128, block_n=128, sparsity=0.5)
book = ztb_from_weight(wf, block_k=128, block_n=128, window=4)
nz = book.tile_nonzero.reshape(-1, 384 // 128)[: 512 // 128]
xf = rng.standard_normal((128, 512)).astype(np.float32)
out = ztb_matmul(jnp.asarray(xf), jnp.asarray(wf), np.asarray(nz),
                 backend="pallas", interpret=True)
np.testing.assert_allclose(np.asarray(out), xf @ wf, rtol=1e-4, atol=1e-3)
stats = book.stats()
print(f"   sparsity={stats.zero_tile_fraction:.2f}, "
      f"fully-sparse windows={stats.fully_sparse_fraction:.2f}, allclose OK")

print("=" * 70)
print("4. Tiny BitNet: one QAT step + one serving decode")
from repro.configs import get_config, reduced
from repro.data import synthetic_batch
from repro.models import build_model
from repro.serve.engine import prepare_params
from repro.train import AdamW, build_train_step, init_train_state

cfg = reduced(get_config("bitnet-1.58b"))
api = build_model(cfg)
opt = AdamW(lr=1e-3)
state = init_train_state(api, opt, jax.random.PRNGKey(0))
step = jax.jit(build_train_step(api, opt))
batch = {k: jnp.asarray(v) for k, v in
         synthetic_batch(cfg, batch=2, seq=64, step=0).items()}
state, metrics = step(state, batch)
print(f"   QAT train step: loss={float(metrics['loss']):.3f}")
params = prepare_params(state.params)
cache = api.init_cache(1, 80)
logits, cache = api.prefill(params, {"tokens": batch["tokens"][:1]}, cache)
tok = int(jnp.argmax(logits[0, -1]))
logits, cache = api.decode(params, jnp.array([tok]), cache, jnp.int32(64))
print(f"   served (ternary weights): first sampled token={tok}")

print("=" * 70)
print("5. Legion Machine — one attention stage executed through the plan")
import dataclasses

from repro.core.workloads import attention_workloads as _wl, bitnet_1_58b_kv
from repro.legion import Machine

spec = dataclasses.replace(bitnet_1_58b_kv(seq_len=128), layers=1)
score = _wl(spec)[1]          # Q @ K^T per head, int8, GQA KV multicast
cfg_leg = dlegion()
machine = Machine(cfg_leg)
res = machine.run(score)      # plan + synthesize + execute + validate
assert res.ok                 # traffic AND cycles within 5% of simulate()
tot, sim = res.trace.totals, simulate(cfg_leg, [score]).stages[score.stage]
print(f"   {score.stage}: {score.count} heads on {cfg_leg.units} Legions, "
      f"mode={res.mode.name}, outputs={res.outputs.shape} == x @ w: OK")
print(f"   measured  weight={tot.weight_bytes / 1e6:6.3f} MB  "
      f"act={tot.act_bytes / 1e6:6.3f} MB  psum={tot.psum_bytes / 1e6:6.3f} MB")
print(f"   analytic  weight={sim.weight_bytes / 1e6:6.3f} MB  "
      f"act={sim.act_bytes / 1e6:6.3f} MB  psum={sim.psum_bytes / 1e6:6.3f} MB")
print(f"   NoC multicast deduped {res.trace.multicast_hits} tile transfers")

print("=" * 70)
print("6. Serve-path Legion backend — one decode step through the Machine")
from repro.serve.legion_backend import LegionServeBackend

backend = LegionServeBackend(cfg_leg, cfg, params)   # SS4's served weights
tally = backend.step_tally(1, (16,))   # one decode token at context 16
tvals, cvals = backend.cross_validate(m=1, contexts=(16,))
assert all(v.ok for v in tvals + cvals)
print(f"   {tally.gemms} GEMMs lowered to one Program and executed: "
      f"wq/wk/wv/wo + w1/w2/w3 projections AND the act-to-act attention "
      f"stages\n   (KV cache as stationary operands, K/N = context 16)")
print(f"   per decode token: {tally.cycles} cycles "
      f"({tally.seconds(cfg_leg.freq_hz) * 1e6:.2f} us @ 1 GHz), "
      f"weight={tally.weight_bytes / 1e3:.1f} KB, "
      f"act={tally.act_bytes / 1e3:.1f} KB")
worst = max(v.rel_err for v in cvals)
print(f"   measured vs simulate() on the same workloads: "
      f"worst cycle error {worst * 100:.2f}% — serve path cross-validated")

print("=" * 70)
print("7. Machine session API — instruments + executor backends")
from repro.legion import Instrument, ShardedExecutor


class PassCounter(Instrument):
    """Custom instrument: count executed vs ZTB-skipped passes."""

    def __init__(self):
        self.executed = 0
        self.skipped = 0

    def on_pass(self, **event):
        self.executed += 1

    def on_window_skip(self, **event):
        self.skipped += 1


probe = PassCounter()
machine = Machine(cfg_leg, instruments=[probe])   # session-lifetime hook
rep = machine.run(score)                          # fresh tracer+counter/run
print(f"   instrument saw {probe.executed} executed passes; report merges "
      f"weight={rep.traffic.weight_bytes / 1e6:.3f} MB, "
      f"{rep.total_cycles} cycles, validation ok={rep.ok}")

sharded = Machine(cfg_leg, backend=ShardedExecutor())
rep_sh = sharded.run(score)   # Legion axis on a JAX mesh axis (shard_map)
assert np.array_equal(rep.outputs, rep_sh.outputs)   # bit-exact parity
assert rep_sh.trace.totals == rep.trace.totals
print(f"   ShardedExecutor on {sharded.backend.devices_used} device(s): "
      f"outputs bit-exact, traffic/cycles identical "
      f"(run with XLA_FLAGS=--xla_force_host_platform_device_count=8 to "
      f"spread 8 Legions)")

print("=" * 70)
print("8. Program graph API — whole attention block, pipelined")
from repro.legion import PipelinedExecutor, lower_attention, reference_outputs

block = lower_attention(spec)                 # QKV -> score -> out -> O-proj
piped = Machine(cfg_leg, backend=PipelinedExecutor())
prep = piped.run(block)                       # ProgramReport
assert prep.ok                                # every stage at 0% vs simulate()
ref = reference_outputs(block)                # pure-NumPy graph execution
assert all(np.array_equal(prep.outputs[k], ref[k]) for k in ref)
print(f"   {len(block)} stages ({' -> '.join(block.names)})")
print(f"   act-to-act stages executed as real GEMMs (K/V stationary, GQA "
      f"multicast); all outputs == NumPy reference")
pp = prep.pipeline
print(f"   chain graph: overlapped == serial == {pp.serial_cycles} cycles "
      f"(dependency chains cannot overlap)")
split = lower_attention(spec, split_qkv=True)  # q/k/v independent stages
pp2 = piped.run(split).pipeline
print(f"   split q/k/v graph: serial={pp2.serial_cycles} -> "
      f"overlapped={pp2.overlapped_cycles} cycles "
      f"({pp2.speedup:.3f}x, {pp2.hidden_cycles} fill/pipeline cycles "
      f"hidden under independent streams)")

print("=" * 70)
print("9. Serve pipelining — multi-layer programs + merged decode batches")
# Two EXPLICIT transformer layers: layer 1's QKV streams layer 0's MLP
# output through a real cross-layer dependency (no `layers` scalar).
two_layer = backend.step_program(1, (16,), explicit_layers=2)
rep9 = piped.run(two_layer)
assert rep9.ok
print(f"   two-layer step program: {len(two_layer)} stages, "
      f"qkv_proj@1 depends on {two_layer['qkv_proj@1'].deps} — "
      f"explicit cross-layer dep, 0% xval per stage")

# One decode step's merged batch graph: two slots at different contexts,
# per-slot attention interleaved as an antichain under shared projections.
merged = backend.step_program(2, (12, 20))
pp9 = piped.run(merged).pipeline
print(f"   merged 2-slot decode batch: serial={pp9.serial_cycles} -> "
      f"overlapped={pp9.overlapped_cycles} cycles "
      f"({pp9.speedup:.3f}x — slots hide each other's fill/pipeline)")

# Engine view: the overlapped per-token cycles feed the KV-cache budget.
serial9, overlapped9 = backend.step_pipeline(2, (12, 20))
from repro.serve.kv_cache import plan as kv_plan
budget = kv_plan(cfg, batch=2, max_seq=64, hbm_bytes_per_chip=16e9,
                 chips=1, cycles_per_token=overlapped9 / 2,
                 freq_hz=cfg_leg.freq_hz,
                 serial_cycles_per_token=serial9 / 2)
print(f"   engine view: {budget.tokens_per_sec:,.0f} tokens/s/slot "
      f"overlapped (pipelining x{budget.pipelining_speedup:.3f} vs "
      f"serial) -> latency-aware KV-cache admission")

print("=" * 70)
print("10. Observability — timeline trace export + metrics registry")
import os
import tempfile

from repro.obs import MetricsRegistry, TimelineTracer

tracer = TimelineTracer(cfg_leg)
reg = MetricsRegistry()
obs_machine = Machine(cfg_leg, backend=PipelinedExecutor(),
                      instruments=[tracer], metrics=reg)
rep10 = obs_machine.run(merged)               # the 2-slot decode batch
# the tracer rebuilds the timeline from Instrument events alone, yet
# lands on the counter's cycles EXACTLY — serial and overlapped both
assert tracer.serial_cycles() == rep10.serial_cycles
assert tracer.overlapped_cycles() == rep10.total_cycles
path = os.path.join(tempfile.mkdtemp(), "trace.json")
tracer.export(path)
tl = tracer.programs[-1]
print(f"   traced {len(tl.cells)} round slices across "
      f"{len(tl.stage_order)} stages: serial makespan "
      f"{tracer.serial_cycles()} == counter, overlapped "
      f"{tracer.overlapped_cycles()} == pipeline report")
print(f"   Chrome trace written to {path} — open in ui.perfetto.dev "
      f"(pid 0 = serial placement, pid 1 = overlapped)")
snap = reg.snapshot()
print(f"   metrics: {len(snap)} families; "
      f"machine_cycles={snap['machine_cycles']['series']['']:.0f}, "
      f"machine_passes={snap['machine_passes']['series']['']:.0f}, "
      f"pipeline speedup p50="
      f"{reg.get('machine_pipeline_speedup').percentile(50):.3f}x")

print("=" * 70)
print("11. In-flight batching — chunked prefill merged with decode + "
      "live admission")
from repro.serve import LiveAdmission, ServeEngine

# Chunked prefill is BIT-EXACT vs whole-prompt prefill, so the in-flight
# engine emits exactly the tokens the legacy engine does — while merging
# each step's prefill chunks with the batched decode into ONE Program.
ifb_backend = LegionServeBackend(cfg_leg, cfg, params)
eng11 = ServeEngine(api, params, max_slots=3, max_seq=64,
                    prefill_chunk_tokens=8,
                    admission=LiveAdmission(ifb_backend,
                                            hbm_bytes_per_chip=8 << 30))
ifb_backend.attach(eng11)
prompts11 = [np.arange(1, 4 + 3 * i) for i in range(4)]
reqs11 = [eng11.submit(p, max_new_tokens=3 + i % 2)
          for i, p in enumerate(prompts11)]
done11 = eng11.run_until_done()

legacy11 = ServeEngine(api, params, max_slots=3, max_seq=64)
legacy_reqs = [legacy11.submit(p, max_new_tokens=3 + i % 2)
               for i, p in enumerate(prompts11)]
legacy11.run_until_done()
assert [r.output for r in reqs11] == \
    [r.output for r in legacy_reqs]                      # bit-exact

s11 = ifb_backend.summary()
mixed = sum(1 for e in eng11.step_log if e["phase"] == "prefill_chunk")
print(f"   {len(done11)} requests, {mixed} prefill chunks merged into "
      f"{s11['engine_steps']} engine steps (one Program each)")
print(f"   engine view incl. prefill: "
      f"overlapped {s11['overlapped_cycles_per_step']:.0f} <= "
      f"serial {s11['serial_cycles_per_step']:.0f} cycles/step "
      f"(x{s11['pipeline_speedup']:.3f})")
print(f"   live admission on the measured budget: "
      f"{eng11.admission.stats.admitted} admitted, "
      f"{eng11.admission.stats.deferred} deferred, "
      f"{eng11.admission.stats.refused} refused; window truncations "
      f"flagged: {sum(r.truncated for r in done11)}")

print("=" * 70)
print("12. Paged KV cache — block allocator, forced preemption, "
      "page-priced traffic")
from repro.serve import PagedKVCache

# Pool squeezed to 8 pages x 4 tokens — exactly one max_seq=32 window
# shared by three slots — forcing mid-decode evictions (pages freed, request re-queued
# for re-prefill) — yet every output stays BIT-EXACT vs the contiguous
# engine, because re-prefill replays prompt + generated-so-far.
prompts12 = [np.arange(1, 5 + 2 * i) for i in range(5)]
paged12 = PagedKVCache(total_pages=8, page_tokens=4)
pg_backend = LegionServeBackend(cfg_leg, cfg, params, page_tokens=4)
eng12 = ServeEngine(api, params, max_slots=3, max_seq=32, paged_kv=paged12)
pg_backend.attach(eng12)
reqs12 = [eng12.submit(p, max_new_tokens=6) for p in prompts12]
eng12.run_until_done()

ref12 = ServeEngine(api, params, max_slots=3, max_seq=32)
ref_reqs = [ref12.submit(p, max_new_tokens=6) for p in prompts12]
ref12.run_until_done()
assert [r.output for r in reqs12] == \
    [r.output for r in ref_reqs]                         # bit-exact

st12 = paged12.allocator.stats()
preempts = sum(1 for e in eng12.step_log if e["phase"] == "preempt")
assert preempts > 0 and st12.pinned_pages == 0
s12 = pg_backend.summary()
print(f"   {len(reqs12)} requests through {st12.total_pages} pages of "
      f"{paged12.page_tokens} tokens: {preempts} preemptions "
      f"({st12.evictions} evictions), outputs bit-exact vs contiguous")
print(f"   page-priced traffic: {s12['page_fetches']:.0f} fetches, "
      f"{s12['page_fetch_bytes'] / 1024:.1f} KiB, last-page waste "
      f"{s12['page_waste_frac']:.1%} of page bytes "
      f"(serial cycles unchanged by construction)")

print("=" * 70)
print("13. Finite bandwidth — the stall knee, a cross-validated sweep, "
      "and per-stage roofline points")
from repro.legion import (find_stall_knee, hbm_bytes_per_cycle,
                          sweep_bandwidth)
from repro.obs import RooflineTracer

# How much fetch bandwidth does this attention block actually need?
# find_stall_knee bisects for the smallest stall-free bytes/cycle; the
# paper's budget (128 GB/s per Legion) sits far above it.
wl13 = attention_workloads(spec)
knee = find_stall_knee(cfg_leg, wl13)
budget = hbm_bytes_per_cycle(cfg_leg)
sweep = sweep_bandwidth(cfg_leg, wl13, [knee / 4, knee * 2],
                        cross_validate=True)
assert sweep.worst_rel_err == 0.0      # counted stall == analytic stall
assert sweep.points[0].stalled and not sweep.points[1].stalled
print(f"   stall knee at {knee:.1f} B/cycle "
      f"({budget / knee:.0f}x headroom under the paper budget); "
      f"quarter-knee run stalls {sweep.points[0].stall_frac:.0%} "
      f"of its cycles, cross-validated at 0% error")

# A RooflineTracer rides a below-knee Machine and reduces the event
# stream to one point per stage: intensity, stall_frac, efficiency.
mach13 = Machine(cfg_leg, mem_bw_bytes_per_cycle=knee / 2)
tr13 = mach13.add_instrument(RooflineTracer())
for w in wl13:
    mach13.run(w, check_outputs=False, validate=False)
for p in tr13.rows():
    assert p.efficiency <= 1.0
    bound = "memory" if p.memory_bound else "compute"
    print(f"   {p.stage:<10} {p.mode:<6} {p.arithmetic_intensity:7.1f} "
          f"ops/B  stall {p.stall_frac:5.1%}  eff {p.efficiency:.2f} "
          f"({bound}-bound, {p.legions_used} Legions)")

print("=" * 70)
print("14. Workload zoo — MoE expert skip and the Mamba-2 SSD scan "
      "through the unified legion.lower(spec)")
from repro.legion import lower, moe_stage_names, zoo_spec
from repro.models.mamba2 import ssd_lowering_spec
from repro.models.moe import moe_lowering_spec

# A granite-MoE FFN block: the router's top-k becomes program-level ZTB
# sparsity — unchosen experts lower to fully-skipped windows, and the
# traffic delta vs the dense-E twin is EXACTLY their stationary bytes.
moe_cfg = reduced(get_config("granite-moe-1b-a400m"))
spec14 = moe_lowering_spec(moe_cfg, tokens=16)
prog14 = lower(spec14)                       # == lower(zoo_spec(moe_cfg))
rep14 = Machine(cfg_leg).run(prog14)
ref14 = reference_outputs(prog14)
assert rep14.ok
for name in ref14:                           # skipped experts included
    assert np.array_equal(rep14.outputs[name], ref14[name])
chosen14, skipped14 = spec14.routing()
dense14 = Machine(cfg_leg).run(
    lower(dataclasses.replace(spec14, top_k=spec14.n_experts, chosen=None)))
wb = lambda rep: sum(rep[n].traffic.weight_bytes for n in rep.outputs)
skipped_bytes = sum(dense14[n].traffic.weight_bytes
                    for e in skipped14 for n in moe_stage_names(e))
assert wb(rep14) == wb(dense14) - skipped_bytes          # exact identity
print(f"   MoE {spec14.n_experts} experts, top-{spec14.top_k} "
      f"(chose {list(chosen14)}): {wb(rep14) / 1024:.1f} KiB weights vs "
      f"{wb(dense14) / 1024:.1f} KiB dense — skip saves "
      f"{wb(dense14) / wb(rep14):.2f}x, bit-exact incl. skipped experts")

# The mamba2 SSD scan: chunked state/output GEMMs with the recurrent
# state threaded across chunks as a stationary multi-producer Ref.
ssm_cfg = reduced(get_config("mamba2-130m"))
prog14b = lower(ssd_lowering_spec(ssm_cfg, chunks=2))
rep14b = Machine(cfg_leg).run(prog14b)
assert rep14b.ok
ref14b = reference_outputs(prog14b)
assert all(np.array_equal(rep14b.outputs[k], ref14b[k]) for k in ref14b)
print(f"   SSD scan {ssm_cfg.ssm_heads} heads x 2 chunks of "
      f"{ssm_cfg.ssd_chunk}: {len(prog14b)} stages, bit-exact, "
      f"state carried as a cross-chunk stationary Ref")
print("quickstart complete.")
