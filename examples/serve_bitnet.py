"""End-to-end serving driver (the paper's kind: inference acceleration):
offline-quantize a BitNet-style model to ternary weights and stream batched
requests through the continuous-batching engine.  With ``--legion`` (on by
default) every prefill/decode step's projection GEMMs also execute through
the D-Legion runtime, producing per-request traffic and cycle tallies.

    PYTHONPATH=src python examples/serve_bitnet.py --requests 12 --slots 4
"""
import argparse
import time

import jax
import numpy as np

from repro.models import build_model
from repro.serve import ServeEngine
from repro.serve.engine import prepare_params
from repro.serve.kv_cache import plan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bitnet-1.58b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (needs a real accelerator)")
    ap.add_argument("--no-legion", action="store_true",
                    help="skip the D-Legion serve backend tallies")
    ap.add_argument("--legions", type=int, default=8,
                    help="Legion count for the accelerator model")
    ap.add_argument("--sharded", action="store_true",
                    help="run step GEMMs through the ShardedExecutor "
                         "(Legion axis on a JAX mesh axis; set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8 first)")
    args = ap.parse_args()

    from repro.configs import get_config, reduced
    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = reduced(cfg)
    api = build_model(cfg)

    budget = plan(cfg, batch=args.slots, max_seq=args.max_seq,
                  hbm_bytes_per_chip=16e9, chips=1)
    print(f"arch={cfg.name}  kv-bytes/token={budget.bytes_per_token}  "
          f"cache={budget.total_bytes/1e6:.1f}MB  fits={budget.fits_hbm}")

    params = api.init(jax.random.PRNGKey(0))
    params = prepare_params(params)   # offline ternary quantization
    eng = ServeEngine(api, params, max_slots=args.slots,
                      max_seq=args.max_seq)

    backend = None
    if not args.no_legion:
        from repro.core import dlegion
        from repro.legion import ShardedExecutor
        from repro.serve import LegionServeBackend

        accel = dlegion(legions=args.legions)
        executor = ShardedExecutor() if args.sharded else None
        backend = LegionServeBackend(accel, cfg, params,
                                     executor=executor).attach(eng)
        print(f"legion backend attached: {accel.name}, every step lowered "
              f"to a Program (projections + act-to-act attention over the "
              f"KV context) through a Machine session "
              f"({backend.machine.backend.name} executor)")

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab, size=rng.integers(4, 24))
        eng.submit(prompt, max_new_tokens=args.max_new)
    done = eng.run_until_done()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests / {tokens} tokens in {dt:.2f}s "
          f"({tokens/dt:.1f} tok/s on this host)")
    for r in done[:3]:
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> {r.output}")

    if backend is not None:
        s = backend.summary()
        print(f"D-Legion tallies ({s['prefill_steps']} prefills, "
              f"{s['decode_steps']} decode steps through the runtime):")
        print(f"  per decode token: {s['cycles_per_decode_token']} cycles "
              f"({s['us_per_decode_token']:.3f} us @ 1 GHz)")
        print(f"  total: {s['cycles'] / 1e3:.1f} kcycles, "
              f"weight={s['weight_bytes'] / 1e6:.2f} MB, "
              f"act={s['act_bytes'] / 1e6:.2f} MB, "
              f"psum={s['psum_bytes'] / 1e6:.2f} MB")
        for uid in sorted(backend.per_request)[:3]:
            t = backend.per_request[uid]
            print(f"  req {uid}: prefill[{t.prefill_tokens}] + "
                  f"decode[{t.decode_tokens}] -> {t.cycles} cycles, "
                  f"{t.mem_bytes / 1e3:.1f} KB moved")
        tv, cv = backend.cross_validate(m=1, contexts=(16,))
        worst = max([e for v in tv for e in v.errors.values()]
                    + [v.rel_err for v in cv])
        assert all(v.ok for v in tv + cv)
        print(f"  cross-validated vs simulate() ({len(tv)} stage families, "
              f"attention included): worst error {worst * 100:.2f}% — OK")

        # latency-aware admission: measured decode cycles -> tokens/sec
        budget = plan(cfg, batch=args.slots, max_seq=args.max_seq,
                      hbm_bytes_per_chip=16e9, chips=1,
                      cycles_per_token=s["cycles_per_decode_token"],
                      freq_hz=accel.freq_hz)
        print(f"  latency-aware cache budget: "
              f"{budget.tokens_per_sec:,.0f} tok/s per slot "
              f"({budget.batch_tokens_per_sec:,.0f} across {args.slots} "
              f"slots), {budget.seconds_to_fill(args.max_seq) * 1e3:.2f} ms "
              f"to fill a {args.max_seq}-token window")

        # engine view: each batched decode step as one merged graph
        # through the pipelined schedule (overlapped <= serial, asserted)
        if s["decode_steps"]:
            piped = backend.cache_budget(
                batch=args.slots, max_seq=args.max_seq,
                hbm_bytes_per_chip=16e9, chips=1)
            print(f"  engine view (merged batch graphs, pipelined): "
                  f"{s['overlapped_cycles_per_step']:.0f} of "
                  f"{s['serial_cycles_per_step']:.0f} cycles/step "
                  f"(x{s['pipeline_speedup']:.3f}) -> "
                  f"{piped.tokens_per_sec:,.0f} tok/s per slot overlapped")


if __name__ == "__main__":
    main()
